"""Batch schedulers: the paper's SLO-ODBS (Algorithm 1) and its SLO-DBS /
ODBS projections, plus the FIFO and S³-style bin-packing baselines it is
evaluated against (§5.2).

Faithfulness notes
------------------
* Algorithm 1 is implemented literally: requests sorted by SLO ascending; a
  running batch is closed when the weighted composite
  ``w1·T_l + w2·T_o`` exceeds the threshold; the batch-size cap is adjusted
  from the composite metric CM (line 20 — the paper does not spell the rule
  out; we use a monotone cap, documented below).
* The paper's prose swaps which weight the SLO-DBS/ODBS names zero out
  (w1=0 is called "SLO-DBS" although w1 multiplies the SLO term).  We follow
  the *intent* established by Fig. 4 — SLO-DBS optimizes violations, ODBS
  optimizes latency — and keep the generic (w1, w2) surface so either reading
  is reproducible.  See EXPERIMENTS.md §Fidelity.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Callable, Iterable, Optional

from repro.core.types import Batch, Request


@dataclass
class SchedulerConfig:
    w1: float = 1.0                # weight of the latency/SLO term
    w2: float = 1.0                # weight of the output-length term
    threshold: float = 2.5e4       # composite budget per batch (tuned: §bench)
    l1: float = 1.0                # parallel-overhead factor on T_l (paper Eq.1)
    l2: float = 1.0                # parallel-overhead factor on T_o (paper Eq.2)
    max_batch: int = 64            # hardware cap
    memory_budget: float = 16e9    # KV budget per replica (bytes)
    base_cap: int = 64             # CM-driven dynamic cap baseline (line 20)
    # cache-aware batching (beyond-paper; serving.prefix_cache): group
    # shared-prefix requests into the same batch window so the radix tree
    # serves their hits while the blocks are hot
    prefix_aware: bool = False
    prefix_block: int = 16         # tokens of leading prompt that define a group
    # speculative decoding (beyond-paper; serving.speculative): expected
    # tokens emitted per engine iteration (= spec_speedup(K, acceptance)).
    # The composite's output term counts decode *iterations*, so speculation
    # widens the effective per-batch decode budget by this factor
    spec_speedup: float = 1.0

    def with_speculation(self, spec_tokens: int,
                         acceptance: float) -> "SchedulerConfig":
        """This config re-priced at a (K, acceptance) operating point —
        the one constructor every serve path uses, so the measured-
        acceptance EMA flows into the composite the same way everywhere."""
        import dataclasses
        return dataclasses.replace(
            self, spec_speedup=spec_speedup(spec_tokens, acceptance))


def spec_speedup(spec_tokens: int, acceptance: float) -> float:
    """Expected tokens emitted per verify iteration under greedy speculative
    decoding with window K and i.i.d. per-draft acceptance probability a:
    ``E = 1 + a + a^2 + ... + a^K = (1 - a^(K+1)) / (1 - a)`` (the run of
    accepted drafts plus the always-emitted bonus token)."""
    k = max(0, int(spec_tokens))
    a = min(max(float(acceptance), 0.0), 1.0)
    if k == 0:
        return 1.0
    if a >= 1.0:
        return float(k + 1)
    return (1.0 - a ** (k + 1)) / (1.0 - a)


def prefix_affinity_key(requests: list, block: int = 16
                        ) -> Callable[[Request], tuple]:
    """Cache-aware sort key for slo_odbs: requests sharing their first KV
    block sort adjacently (one prefill computes the prefix, the rest hit the
    radix tree), and groups are ordered by their most urgent member's SLO so
    affinity never strands a tight deadline behind a slack group."""
    urgency: dict[tuple, float] = {}
    for r in requests:
        key = tuple(r.tokens[:block])
        urgency[key] = min(urgency.get(key, float("inf")), r.slo)

    def sort_key(r: Request) -> tuple:
        key = tuple(r.tokens[:block])
        return (urgency[key], key, r.slo)
    return sort_key


def _dynamic_cap(cm: float, cfg: SchedulerConfig) -> int:
    """Paper line 20: 'dynamically adjust batch size according to CM'.
    Interpretation (documented): the heavier the current composite metric,
    the smaller the cap — halving per threshold multiple."""
    if cm <= 0:
        return cfg.max_batch
    scale = 1.0 + cm / max(cfg.threshold, 1e-9)
    return max(1, min(cfg.max_batch, int(cfg.base_cap / scale) + 1))


def derive_chunk_tokens(cfg: SchedulerConfig, *, block_size: int = 16,
                        max_chunk_blocks: int = 16) -> int:
    """Per-iteration prefill token budget for the paged engine's chunked
    prefill, derived from the batch-close composite threshold.

    Interpretation (the paper stops at batch shaping; iteration-level
    scheduling is our extension): the threshold is the per-batch composite
    latency budget, so a scheduler configured with a *larger* threshold
    tolerates longer uninterrupted work — larger prefill chunks, fewer
    interleave breaks — while heavier composite weights tighten the
    per-iteration budget.  The rule is the same monotone-shape choice as
    ``_dynamic_cap``: chunk blocks scale with ``threshold / (w1 + w2)``
    (1e3 composite units ~ one KV block of prefill), clamped to
    [1, max_chunk_blocks] blocks so a chunk is never smaller than the
    scatter granularity nor larger than a whole scheduling window."""
    w = max(cfg.w1 + cfg.w2, 1e-9)
    blocks = int(cfg.threshold / w / 1e3)
    return block_size * max(1, min(max_chunk_blocks, blocks))


def slo_odbs(requests: Iterable[Request], cfg: SchedulerConfig,
             *, sort_key: Optional[Callable[[Request], float]] = None
             ) -> list[Batch]:
    """Algorithm 1 (SLO and Output-Driven Dynamic Batch Scheduler).  With
    ``cfg.prefix_aware`` (and no explicit sort_key) requests are grouped by
    shared leading prompt block before the SLO-ascending walk, so batches
    pack prefix-cache hits together."""
    reqs = list(requests)
    if sort_key is None and cfg.prefix_aware:
        sort_key = prefix_affinity_key(reqs, cfg.prefix_block)
    reqs = sorted(reqs, key=sort_key or (lambda r: r.slo))
    batches: list[Batch] = []
    cur = Batch()
    l_cm = o_cm = cm = 0.0
    # speculation compresses output length into fewer engine iterations, so
    # the output term is charged in expected *iterations*, not tokens
    sp = max(cfg.spec_speedup, 1.0)
    for q in reqs:
        t_l = (q.slo + l_cm) * (len(cur) + 1) * cfg.l1
        t_o = (q.sched_output_len + o_cm) / sp * (len(cur) + 1) * cfg.l2
        total = cfg.w1 * t_l + cfg.w2 * t_o
        kv_after = sum(r.kv_bytes_estimate for r in cur.requests) + q.kv_bytes_estimate
        cap = _dynamic_cap(cm, cfg)
        if len(cur) == 0 or (total <= cfg.threshold and len(cur) < cap
                             and kv_after <= cfg.memory_budget):
            cur.requests.append(q)
            l_cm = max(l_cm, q.slo)
            o_cm = max(o_cm, q.sched_output_len)
            # CM mirrors the batch-close composite: w1 weighs the SLO term,
            # w2 the output term (a historical swap here capped SLO-DBS on
            # output length and ODBS on deadlines — each projection's cap
            # must respond to its own term only)
            cm = max(cm, cfg.w1 * q.slo + cfg.w2 * q.sched_output_len / sp)
        else:
            batches.append(cur)
            cur = Batch(requests=[q])
            l_cm, o_cm = q.slo, q.sched_output_len
            cm = cfg.w1 * q.slo + cfg.w2 * q.sched_output_len / sp
    if len(cur):
        batches.append(cur)
    return batches


def slo_dbs(requests, cfg: SchedulerConfig) -> list[Batch]:
    """SLO-focused projection: composite reduces to the SLO/latency term;
    packing is driven purely by deadline affinity."""
    c = SchedulerConfig(**{**cfg.__dict__, "w1": 1.0, "w2": 0.0})
    return slo_odbs(requests, c)


def odbs(requests, cfg: SchedulerConfig) -> list[Batch]:
    """Output-driven projection: requests are grouped by *predicted output
    length* (the S³ insight) — sort by length, pack by the output term."""
    c = SchedulerConfig(**{**cfg.__dict__, "w1": 0.0, "w2": 1.0})
    return slo_odbs(requests, c, sort_key=lambda r: r.sched_output_len)


# ------------------------------------------------------------------ baselines

def fifo(requests, cfg: SchedulerConfig, batch_size: int = 8) -> list[Batch]:
    """Default batching (paper Fig. 3/4 baseline): arrival order, fixed size."""
    reqs = sorted(requests, key=lambda r: r.arrival)
    return [Batch(requests=list(reqs[i:i + batch_size]))
            for i in range(0, len(reqs), batch_size)]


def s3_binpack(requests, cfg: SchedulerConfig) -> list[Batch]:
    """S³ [NeurIPS'23]-style: treat batching as bin packing on predicted
    KV memory to maximize utilization; no SLO awareness (paper §3.2).
    First-fit-decreasing on kv_bytes_estimate."""
    reqs = sorted(requests, key=lambda r: r.kv_bytes_estimate, reverse=True)
    bins: list[tuple[float, Batch]] = []
    out: list[Batch] = []
    for q in reqs:
        placed = False
        for i, (used, b) in enumerate(bins):
            if used + q.kv_bytes_estimate <= cfg.memory_budget \
                    and len(b) < cfg.max_batch:
                b.requests.append(q)
                bins[i] = (used + q.kv_bytes_estimate, b)
                placed = True
                break
        if not placed:
            b = Batch(requests=[q])
            bins.append((q.kv_bytes_estimate, b))
            out.append(b)
    return out


SCHEDULERS: dict[str, Callable] = {
    "slo-odbs": slo_odbs,
    "slo-dbs": slo_dbs,
    "odbs": odbs,
    "fifo": fifo,
    "s3": s3_binpack,
}


def get_scheduler(name: str) -> Callable:
    return SCHEDULERS[name]
