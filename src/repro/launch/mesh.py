"""Production mesh construction (assignment MULTI-POD DRY-RUN step 1).

``make_production_mesh`` is a FUNCTION, not a module-level constant, so
importing this module never touches jax device state.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    # Auto axis_types is make_mesh's default on jax>=0.6 and the only
    # behaviour on 0.4.x (which has no AxisType) — don't pass it explicitly.
    return jax.make_mesh(shape, axes)


def mesh_shape_dict(mesh) -> dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))
