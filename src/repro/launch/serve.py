"""Serving launcher: UELLM pipeline on a real model.

  PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m --reduced \
      --requests 12 --scheduler slo-odbs

``--paged`` serves through the paged continuous-batching runtime instead
(block-table KV, per-prompt prefill, allocator-gated admission); the pool is
sized from ``--kv-budget`` bytes — the same budget surface SLO-ODBS uses.
``--prefix-cache`` layers the radix-tree prefix cache on top (shared-prefix
prompts prefill only their uncached suffix; ``--workload shared-prefix``
generates a template-heavy mix that exercises it), and ``--lookahead N``
lets admission skip a too-big queue head when a later request fits.
``--chunk-tokens N`` chunks prompt prefill to N tokens per engine iteration
(interleaved with decode, so residents never stall for a whole prompt;
``-1`` derives N from the scheduler's composite threshold) and ``--preempt``
lets block pressure evict the slack-most resident for recompute instead of
blocking a tight arrival — both also feed the cluster paths (replica load
projections price them).  ``--speculate`` turns on speculative decoding:
``--drafter`` proposes ``--spec-tokens`` candidates per iteration, verified
in one multi-token kernel pass with greedy acceptance (outputs stay
token-identical; the cluster projections price the *measured* acceptance
EMA — warm-started from ``--profile-in``, bootstrap 0.5 before the first
verify pass).  ``--profile-out``/``--profile-in`` persist and reload the
online cost profile (measured phase-time cells, residuals, acceptance) as
a versioned JSON registry, calibrating every pricing model it reaches —
per replica, with ``--pricing-quantile Q`` switching SLO decisions onto a
tail ratio and ``--profile-half-life N`` bounding the profile's memory so
re-provisioned replicas re-learn.

``--replicas N`` lifts serving to the cluster layer (serving/cluster):
requests are routed by ``--router`` across N replicas.  With ``--paged``
each replica owns a real PagedEngine (pool + prefix cache per replica) and
the routed shares are served live; otherwise the replicas are
LatencyModel-backed simulated engines on per-replica HELR deployments —
the cluster-scale path, which ``--autoscale`` extends with the
forecast-driven elastic replica set (``--workload bursty`` exercises it).
``--models`` turns the simulated cluster into a heterogeneous MLaaS
fleet: a mixed-model, tier-skewed trace is served by per-model replica
pools with model-aware routing, and ``--fleet`` picks between one joint
allocator over the shared replica budget (marginal SLO value, model-swap
actions) and independent per-pool autoscalers.

On a TPU pod this runs under the production mesh with the HELR-mesh plan;
on CPU (--reduced) it serves the reduced config end-to-end.
"""
from __future__ import annotations

import argparse
import hashlib
import json
import time

import jax
import jax.numpy as jnp

from repro.configs import SHAPES, get_config
from repro.core import (LengthPredictor, Monitor, ResourceProfiler,
                        SchedulerConfig, derive_chunk_tokens, get_scheduler,
                        helr_mesh)
from repro.core.profiler import PredictorConfig
from repro.data.workload import (MixedWorkloadConfig, SharedPrefixConfig,
                                 WorkloadConfig, gen_mixed_requests,
                                 gen_requests, gen_shared_prefix_requests,
                                 train_pairs)
from repro.models import api
from repro.obs.calibrate import CalibratedLatencyModel
from repro.obs.export import export_trace, metrics_payload, write_metrics
from repro.obs.profile import CostProfiler
from repro.obs.trace import NULL_TRACER, Tracer
from repro.serving import (AutoscalerConfig, EngineConfig, FaultEvent,
                           FaultPlan, FleetAutoscalerConfig, HealthConfig,
                           InferenceEngine, ModelPoolSpec, PagedEngine,
                           PagedEngineConfig, Replica, RetryConfig, Router,
                           RouterConfig, get_drafter, paper_cluster,
                           simulate_cluster)


def _parse_model_mix(spec: str) -> list:
    """``"arch[:weight],arch[:weight]"`` -> ``[(arch, weight), ...]``."""
    out = []
    for part in spec.split(","):
        part = part.strip()
        if not part:
            continue
        name, _, w = part.partition(":")
        out.append((name.strip(), float(w) if w else 1.0))
    if not out:
        raise SystemExit("--models: empty model list")
    return out


def _make_drafter(args, cfg):
    """Engine drafter from the CLI flags (None lets the engine default)."""
    if args.spec_tokens > 0 and args.drafter == "model":
        return get_drafter("model", draft_cfg=cfg)
    return None


def _spec_acceptance(args, cprof: CostProfiler) -> float:
    """Speculation acceptance for *planning* (replica projections,
    SchedulerConfig.spec_speedup): the cost profiler's measured EMA —
    warm-started from ``--profile-in``, its bootstrap prior when nothing
    has been measured yet, and live-updated by ``PagedEngine._spec_step``
    once serving starts."""
    return cprof.spec_acceptance if args.spec_tokens else 0.0


def _outputs_digest(done: dict) -> str:
    """Order-independent digest of the generated tokens — two serve runs
    printing the same digest emitted identical output streams (the CI
    profile smoke compares this across --profile-out/--profile-in runs)."""
    blob = json.dumps(sorted((int(k), list(map(int, v)))
                             for k, v in done.items()))
    return hashlib.sha1(blob.encode()).hexdigest()[:12]


def _pricing_counters(cal_models) -> dict:
    """Aggregate coverage counters across every ``CalibratedLatencyModel``
    the run priced through (one per replica on the cluster paths)."""
    agg = {"cell_hits": 0, "phase_hits": 0, "cell_misses": 0}
    for m in cal_models:
        c = m.coverage_counters()
        for k in agg:
            agg[k] += c[k]
    total = sum(agg.values())
    agg["covered_frac"] = round(
        (agg["cell_hits"] + agg["phase_hits"]) / total, 4) if total else 0.0
    return agg


def _write_artifacts(args, mon, tracer, cprof, *, latency_s=None,
                     p99_latency_s=None, throughput=None,
                     utilization=None, cal_models=()) -> None:
    """Export the request-lifecycle trace (``--trace``, Chrome/Perfetto JSON)
    and the shared metrics payload (``--metrics-json`` — same schema the
    benchmarks persist).  Latency quantiles default to the monitor's e2e
    histogram when the caller has no direct measurement.  Profiled runs
    also report how calibrated pricing resolved (coverage counters) and
    which replicas drifted — previously they ended silently."""
    st = mon.stats
    if latency_s is None and st.e2e.n:
        latency_s = st.e2e.total / st.e2e.n
    if p99_latency_s is None and st.e2e.n:
        p99_latency_s = st.e2e.quantile(0.99)
    if args.trace:
        obj = export_trace(tracer, args.trace)
        print(f"trace: {len(obj['traceEvents'])} events -> {args.trace}")
    profile_block = cprof.metrics()
    if cal_models:
        profile_block["pricing"] = _pricing_counters(cal_models)
    if args.metrics_json:
        payload = metrics_payload(
            "serve", latency_s=latency_s, p99_latency_s=p99_latency_s,
            throughput=throughput, utilization=utilization,
            slo_attainment=st.slo_attainment if st.slo_observed else None,
            monitor=mon.metrics(), profile=profile_block)
        write_metrics(args.metrics_json, payload)
        print(f"metrics -> {args.metrics_json}")
    if args.profile_in or args.profile_out:
        if cal_models:
            pc = profile_block["pricing"]
            print(f"calibration: cell_hits={pc['cell_hits']} "
                  f"phase_hits={pc['phase_hits']} "
                  f"cell_misses={pc['cell_misses']} "
                  f"covered_frac={pc['covered_frac']}")
        drift = cprof.drift_by_replica()
        by_rep = " by_replica=" + json.dumps(
            {str(r): n for r, n in drift.items()}) if drift else ""
        mdrift = cprof.drift_by_model()
        by_model = " by_model=" + json.dumps(mdrift) if mdrift else ""
        print(f"drift: {cprof.drift_events} events{by_rep}{by_model}")
        mcov = cprof.model_coverage()
        if mcov:
            cov = {m: {p: c["samples"] for p, c in d.items()}
                   for m, d in mcov.items()}
            print(f"model coverage: {json.dumps(cov)}")
    if args.profile_out:
        cprof.save(args.profile_out)
        cov = {p: c["samples"] for p, c in cprof.coverage().items()}
        subs = f"{len(cprof.replica_profiles)} replica"
        if cprof.model_profiles:
            subs += f" + {len(cprof.model_profiles)} model"
        print(f"profile: {len(cprof.cells)} cells, samples {cov}, "
              f"{subs} sub-profiles -> {args.profile_out}")


def _serve_cluster_live(args, cfg, params, mon, reqs, tracer, cprof,
                        cal_models) -> dict:
    """Route requests across N real PagedEngine-backed replicas, then serve
    each replica's share live (per-replica pool + prefix cache)."""
    max_prompt = max(len(r.tokens) for r in reqs)
    max_seq = max(64, -(-(max_prompt + args.max_new) // 8) * 8)
    router = Router(RouterConfig(policy=args.router))
    replicas = []
    for i in range(args.replicas):
        nodes, lat = paper_cluster()
        pcfg = PagedEngineConfig.from_memory_budget(
            cfg, args.kv_budget, max_batch=4, block_size=8,
            max_seq_len=max_seq, max_new_tokens=args.max_new,
            prefix_cache=args.prefix_cache, admit_lookahead=args.lookahead,
            chunk_tokens=args.chunk_tokens, preempt=args.preempt,
            spec_tokens=args.spec_tokens, drafter=args.drafter)
        rep = Replica(
            i, cfg, nodes, lat, max_batch=4, block_size=8,
            n_blocks=pcfg.usable_blocks, prefix_cache=args.prefix_cache,
            chunk_tokens=args.chunk_tokens, preempt=args.preempt,
            spec_tokens=args.spec_tokens,
            spec_acceptance=_spec_acceptance(args, cprof),
            engine=PagedEngine(cfg, params, pcfg, monitor=mon,
                               drafter=_make_drafter(args, cfg),
                               tracer=tracer, track=i,
                               cost_profiler=cprof),
            tracer=tracer)
        if args.profile_in:
            # each replica prices from its own sub-profile (fleet-aggregate
            # fallback); the tail model adds quantile pricing for the
            # SLO-facing projections when --pricing-quantile is set
            rep.price = CalibratedLatencyModel(rep.lm, cprof, replica=i)
            cal_models.append(rep.price)
            if args.pricing_quantile:
                rep.tail = CalibratedLatencyModel(
                    rep.lm, cprof, replica=i,
                    quantile=args.pricing_quantile)
                cal_models.append(rep.tail)
        replicas.append(rep)
    for r in sorted(reqs, key=lambda q: q.arrival):
        rep = router.dispatch(r, replicas, r.arrival)
        if rep is None:
            mon.observe_shed(r)
            continue
        rep.enqueue(r, r.arrival)
    done: dict = {}
    for rep in replicas:
        if not rep.queue:
            continue
        if args.spec_tokens:
            # replicas serve sequentially here, so each one plans at the
            # acceptance the earlier shares already measured
            rep.spec_acceptance = cprof.spec_acceptance
        res = rep.engine.run_continuous(
            sorted(rep.queue, key=lambda q: q.arrival))
        done.update(res.outputs)
        spec = "" if not args.spec_tokens else (
            f", spec acc={res.acceptance_rate:.2f} "
            f"it/tok={res.iterations_per_token:.2f}")
        print(f"replica {rep.rid}: {len(rep.queue)} requests, "
              f"prefill_tokens={res.prefill_tokens}, "
              f"prefix_hits={res.prefix_hits}/{res.prefix_lookups}, "
              f"peak_blocks={res.peak_blocks}{spec}")
    print(f"router: {router.stats.summary()}")
    return done


def _serve_cluster_sim(args, prof, mon, tracer, cprof, cal_models) -> None:
    """Cluster-scale path: LatencyModel-backed replicas on per-replica HELR
    deployments, driven by the discrete-event simulator."""
    full_cfg = get_config(args.arch)
    n = max(args.requests, 128)
    pattern = args.workload if args.workload in ("bursty", "diurnal") \
        else "poisson"
    pools = None
    if args.models:
        # heterogeneous fleet: model-tagged, tier-skewed mixed trace and
        # one replica pool per model over the shared partition budget
        mix = _parse_model_mix(args.models)
        reqs = gen_mixed_requests(MixedWorkloadConfig(
            models=tuple(mix), n_requests=n, arrival_rate=16.0,
            arrival_pattern=pattern, seed=0))
        per = max(1, args.replicas // len(mix))
        pools = [ModelPoolSpec(m, replicas=per, weight=w) for m, w in mix]
    elif args.workload == "shared-prefix":
        reqs = gen_shared_prefix_requests(SharedPrefixConfig(
            n_requests=n, n_templates=max(4, n // 12), prefix_len=96,
            turns=4, arrival_rate=16.0, slo_lo=8.0, slo_hi=60.0, seed=0))
    else:
        reqs = gen_requests(WorkloadConfig(
            n_requests=n, arrival_rate=16.0, arrival_pattern=pattern,
            slo_lo=8.0, slo_hi=60.0, seed=0))
    auto = None
    if args.autoscale:
        if pools is not None and args.fleet == "joint":
            auto = FleetAutoscalerConfig(
                interval=1.0, budget=max(6, 2 * args.replicas),
                min_per_pool=1, spawn_delay=1.0)
        elif pools is not None:
            # replicated per pool by the simulator: independent autoscalers
            auto = AutoscalerConfig(
                interval=1.0, min_replicas=max(1, per),
                max_replicas=max(3, args.replicas), spawn_delay=1.0)
        else:
            auto = AutoscalerConfig(interval=1.0, min_replicas=args.replicas,
                                    max_replicas=max(6, 2 * args.replicas),
                                    spawn_delay=1.0)
    acc = _spec_acceptance(args, cprof)
    sched_cfg = SchedulerConfig()
    if args.spec_tokens:
        sched_cfg = sched_cfg.with_speculation(args.spec_tokens, acc)
    # a warm profile registry calibrates every replica's *pricing* model
    # (projections, shedding, autoscaler capacity) from its own
    # sub-profile; execution physics stay the replica's own analytic
    # model.  --pricing-quantile adds a tail model for the SLO-facing
    # projections (projected_finish, capacity_rps)
    price = tail_price = None
    if args.profile_in and pools is not None:
        # fleet pricing: each replica calibrates from its own sub-profile,
        # falling back to its model's pool aggregate before the fleet view
        def price(lm, rid, model):
            m = CalibratedLatencyModel(lm, cprof, replica=rid, model=model)
            cal_models.append(m)
            return m
        if args.pricing_quantile:
            def tail_price(lm, rid, model):
                m = CalibratedLatencyModel(lm, cprof, replica=rid,
                                           model=model,
                                           quantile=args.pricing_quantile)
                cal_models.append(m)
                return m
    elif args.profile_in:
        def price(lm, rid):
            m = CalibratedLatencyModel(lm, cprof, replica=rid)
            cal_models.append(m)
            return m
        if args.pricing_quantile:
            def tail_price(lm, rid):
                m = CalibratedLatencyModel(lm, cprof, replica=rid,
                                           quantile=args.pricing_quantile)
                cal_models.append(m)
                return m
    faults = retry = health = None
    if args.fault_crash or args.fault_mtbf > 0:
        events = []
        for spec in (args.fault_crash or "").split(","):
            if not spec:
                continue
            ts, _, rid = spec.partition(":")
            events.append(FaultEvent(t=float(ts), kind="crash",
                                     rid=int(rid or 0)))
        faults = FaultPlan(events=events, mtbf=args.fault_mtbf,
                           mttr=args.fault_mttr, seed=args.fault_seed)
        retry = RetryConfig(budget=args.retry_budget,
                            backoff_base=args.retry_backoff)
        tiers = tuple(t for t in (args.brownout_tiers or "").split(",") if t)
        health = HealthConfig(check_interval=args.health_interval,
                              detect_lag=args.detect_lag,
                              brownout_tiers=tiers)
    res = simulate_cluster(
        reqs, full_cfg, get_scheduler(args.scheduler), sched_cfg,
        n_replicas=args.replicas, pools=pools, router=args.router,
        autoscale=auto,
        prefix_cache=args.prefix_cache, chunk_tokens=args.chunk_tokens,
        preempt=args.preempt, spec_tokens=args.spec_tokens,
        spec_acceptance=acc,
        profiler=prof, monitor=mon, tracer=tracer, price=price,
        tail_price=tail_price, faults=faults, retry=retry, health=health)
    print("cluster:", res.summary())
    for s in res.replica_stats:
        tag = f" model={s['model']}" if pools is not None else ""
        print(f"  replica {s['rid']}:{tag} served={s['served']} "
              f"util={s['utilization']} queue_prefill={s['prefill_tokens']} "
              f"saved={s['prefill_tokens_saved']}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--scheduler", default="slo-odbs",
                    choices=["slo-odbs", "slo-dbs", "odbs", "fifo", "s3"])
    ap.add_argument("--continuous", action="store_true",
                    help="beyond-paper continuous batching mode")
    ap.add_argument("--paged", action="store_true",
                    help="paged continuous batching (block-table KV cache)")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="radix-tree prefix sharing over the paged pool "
                         "(implies --paged)")
    ap.add_argument("--lookahead", type=int, default=0,
                    help="queue entries scanned past a blocked head "
                         "(paged admission)")
    ap.add_argument("--chunk-tokens", type=int, default=0,
                    help="per-iteration prefill chunk budget for the paged "
                         "engine (0: whole-prompt prefill at admission; "
                         "-1: derive from the scheduler's composite "
                         "threshold)")
    ap.add_argument("--preempt", action="store_true",
                    help="under block pressure evict the resident with the "
                         "most SLO slack and requeue it for recompute "
                         "instead of blocking a tighter arrival")
    ap.add_argument("--speculate", action="store_true",
                    help="speculative decoding on the paged engine: a "
                         "drafter proposes tokens verified in one "
                         "multi-token kernel pass; greedy acceptance keeps "
                         "outputs token-identical (implies --paged)")
    ap.add_argument("--spec-tokens", type=int, default=4,
                    help="draft tokens verified per engine iteration")
    ap.add_argument("--drafter", default="ngram",
                    choices=["ngram", "model"],
                    help="draft proposer: deterministic n-gram prompt "
                         "lookup (free), or a small draft LM (here: "
                         "randomly initialized stand-in for a distilled "
                         "checkpoint — plumbing demo, low acceptance)")
    ap.add_argument("--workload", default="alpaca",
                    choices=["alpaca", "shared-prefix", "bursty", "diurnal"],
                    help="alpaca: lognormal Poisson mix; shared-prefix: "
                         "template-heavy prompts exercising the prefix cache; "
                         "bursty/diurnal: arrival patterns for --autoscale")
    ap.add_argument("--replicas", type=int, default=1,
                    help="cluster serving: replicas behind the router")
    ap.add_argument("--models", default=None, metavar="SPEC",
                    help="heterogeneous fleet on the simulated cluster: "
                         "comma list of arch[:weight] (e.g. "
                         "'chatglm2-6b:0.6,qwen2-1.5b:0.4').  Requests "
                         "arrive tagged with a model and an SLO tier, "
                         "replicas form per-model pools, and routing is "
                         "model-aware")
    ap.add_argument("--fleet", default="joint",
                    choices=["joint", "independent"],
                    help="with --models --autoscale: one joint allocator "
                         "over the shared replica budget (marginal SLO "
                         "value, model-swap actions) or independent "
                         "per-pool autoscalers")
    ap.add_argument("--router", default="round_robin",
                    choices=["round_robin", "least_loaded", "prefix_affinity",
                             "slo_aware"],
                    help="dispatch policy of the cluster layer")
    ap.add_argument("--autoscale", action="store_true",
                    help="forecast-driven elastic replica set (simulated "
                         "cluster; --replicas becomes the minimum)")
    ap.add_argument("--fault-crash", default=None, metavar="T:RID[,T:RID]",
                    help="inject scripted replica crashes into the cluster "
                         "sim, e.g. '2.5:1' crashes replica 1 at t=2.5s "
                         "(enables fault mode: health checks, retries)")
    ap.add_argument("--fault-mtbf", type=float, default=0.0,
                    help="seeded random faults: mean seconds between "
                         "failures per replica lane (0 = scripted only)")
    ap.add_argument("--fault-mttr", type=float, default=0.0,
                    help="mean recovery time of recoverable random faults")
    ap.add_argument("--fault-seed", type=int, default=0,
                    help="seed of the random fault model")
    ap.add_argument("--retry-budget", type=int, default=2,
                    help="re-dispatches granted to a request lost with a "
                         "failed replica before it counts as shed")
    ap.add_argument("--retry-backoff", type=float, default=0.25,
                    help="base seconds of the exponential retry backoff")
    ap.add_argument("--detect-lag", type=float, default=1.0,
                    help="seconds a silent replica stays routable before "
                         "the health layer declares it down")
    ap.add_argument("--health-interval", type=float, default=0.5,
                    help="heartbeat/health-scan cadence in fault mode")
    ap.add_argument("--brownout-tiers", default=None, metavar="T1[,T2]",
                    help="SLO tiers shed in this order under detected "
                         "capacity loss (graceful brownout), e.g. "
                         "'batch,interactive'")
    ap.add_argument("--kv-budget", type=float, default=2e6,
                    help="paged KV pool budget in bytes (shared with SLO-ODBS)")
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="export the request-lifecycle trace as Chrome/"
                         "Perfetto JSON (load in ui.perfetto.dev)")
    ap.add_argument("--metrics-json", default=None, metavar="PATH",
                    help="write final metrics (incl. latency quantiles) as "
                         "JSON in the shared benchmark schema")
    ap.add_argument("--profile-out", default=None, metavar="PATH",
                    help="save the online cost profile (measured phase-time "
                         "cells + speculative-acceptance EMA) as a versioned "
                         "JSON registry after serving")
    ap.add_argument("--profile-in", default=None, metavar="PATH",
                    help="warm-start from a saved profile registry: pricing "
                         "models calibrate against its measured cells and "
                         "speculation plans at its measured acceptance")
    ap.add_argument("--pricing-quantile", type=float, default=None,
                    metavar="Q",
                    help="price SLO decisions (slo_aware shed/admit, "
                         "autoscaler capacity) at this quantile of the "
                         "measured observed/predicted ratio instead of its "
                         "mean (e.g. 0.95; needs --profile-in; throughput "
                         "projections stay mean-priced)")
    ap.add_argument("--profile-half-life", type=int, default=0,
                    metavar="N",
                    help="decay the profile's calibration statistics with "
                         "this sample half-life (rotating histograms, "
                         "bounded memory) so a throttled/migrated replica "
                         "re-learns; 0 = never forget.  Ignored with "
                         "--profile-in (the registry's setting wins)")
    args = ap.parse_args()
    if args.pricing_quantile is not None \
            and not 0.0 < args.pricing_quantile <= 1.0:
        raise SystemExit("--pricing-quantile must be in (0, 1]")
    if args.autoscale and args.paged:
        raise SystemExit("--autoscale needs the simulated cluster path: "
                         "drop --paged (elasticity has no live-engine mode)")
    if args.models and args.paged:
        raise SystemExit("--models needs the simulated cluster path: "
                         "drop --paged (the heterogeneous fleet has no "
                         "live-engine mode)")
    if (args.prefix_cache or args.speculate) \
            and not (args.replicas > 1 or args.autoscale or args.models):
        args.paged = True          # cluster sim path honors the flags itself
    args.spec_tokens = args.spec_tokens if args.speculate else 0

    # profiling without --trace still needs the span stream: a retain=False
    # tracer is a pure measurement bus (sinks see every event, nothing is
    # stored), so long serve runs profile at O(1) memory
    want_profile = bool(args.profile_in or args.profile_out)
    if args.trace:
        tracer = Tracer()
    elif want_profile:
        tracer = Tracer(retain=False)
    else:
        tracer = NULL_TRACER
    cprof = CostProfiler.load(args.profile_in, tracer=tracer) \
        if args.profile_in else CostProfiler(
            tracer=tracer, half_life=args.profile_half_life or None)
    if want_profile:
        tracer.add_sink(cprof.on_event)
    cal_models: list = []          # CalibratedLatencyModels the run priced by

    if args.chunk_tokens < 0:
        args.chunk_tokens = derive_chunk_tokens(SchedulerConfig(),
                                                block_size=8)
        print(f"chunk budget from scheduler threshold: "
              f"{args.chunk_tokens} tokens/iteration")

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    print(f"serving {cfg.name} "
          f"(plan for production mesh: "
          f"{helr_mesh(get_config(args.arch), SHAPES['decode_32k']).name})")

    if (args.replicas > 1 or args.autoscale or args.models) \
            and not args.paged:
        # cluster-scale path: simulated replicas, no model weights needed
        pred = LengthPredictor(PredictorConfig(), seed=0)
        toks, lens = train_pairs(WorkloadConfig(), 256, seed=1)
        pred.fit(toks, lens, epochs=8)
        prof = ResourceProfiler(pred, get_config(args.arch))
        mon = Monitor(prof)
        cprof.monitor = mon            # drift attribution lands in metrics
        _serve_cluster_sim(args, prof, mon, tracer, cprof, cal_models)
        print("monitor:", mon.metrics())
        _write_artifacts(args, mon, tracer, cprof, cal_models=cal_models)
        return

    params = api.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    engine = InferenceEngine(cfg, params,
                             EngineConfig(max_batch=4, cache_len=64,
                                          max_new_tokens=args.max_new))

    if args.workload == "shared-prefix":
        reqs = gen_shared_prefix_requests(SharedPrefixConfig(
            n_requests=args.requests, n_templates=max(2, args.requests // 6),
            prefix_len=16, suffix_mean=2.0, vocab=cfg.vocab_size, seed=0))
        for r in reqs:
            r.tokens = [t % cfg.vocab_size for t in r.tokens[:32]]
    else:
        pattern = args.workload if args.workload in ("bursty", "diurnal") \
            else "poisson"
        reqs = gen_requests(WorkloadConfig(n_requests=args.requests, seed=0,
                                           vocab=cfg.vocab_size,
                                           arrival_pattern=pattern))
        for r in reqs:
            r.tokens = [t % cfg.vocab_size for t in r.tokens[:16]]
    for r in reqs:
        r.input_len = len(r.tokens)
        r.true_output_len = r.true_output_len % args.max_new + 1

    pred = LengthPredictor(PredictorConfig(vocab=cfg.vocab_size), seed=0)
    toks, lens = train_pairs(WorkloadConfig(vocab=cfg.vocab_size), 256, seed=1)
    pred.fit(toks, lens, epochs=8)
    prof = ResourceProfiler(pred, cfg)
    mon = Monitor(prof)
    cprof.monitor = mon                # drift attribution lands in metrics
    prof.profile(reqs)

    t0 = time.perf_counter()
    if args.replicas > 1 and args.paged:
        done = _serve_cluster_live(args, cfg, params, mon, reqs, tracer,
                                   cprof, cal_models)
    elif args.paged:
        # size the block tables for the longest admitted prompt plus the
        # decode budget so any --max-new value is admissible
        max_prompt = max(len(r.tokens) for r in reqs)
        max_seq = max(64, -(-(max_prompt + args.max_new) // 8) * 8)
        pcfg = PagedEngineConfig.from_memory_budget(
            cfg, args.kv_budget, max_batch=4, block_size=8,
            max_seq_len=max_seq, max_new_tokens=args.max_new,
            prefix_cache=args.prefix_cache,
            admit_lookahead=args.lookahead,
            chunk_tokens=args.chunk_tokens, preempt=args.preempt,
            spec_tokens=args.spec_tokens, drafter=args.drafter)
        print(f"paged pool: {pcfg.usable_blocks} usable blocks (+null) x "
              f"{pcfg.block_size} slots ({args.kv_budget:.0f} B budget, "
              f"prefix_cache={'on' if pcfg.prefix_cache else 'off'}, "
              f"chunk_tokens={pcfg.chunk_tokens}, "
              f"preempt={'on' if pcfg.preempt else 'off'}, "
              f"speculate={pcfg.spec_tokens or 'off'})")
        paged = PagedEngine(cfg, params, pcfg, monitor=mon,
                            drafter=_make_drafter(args, cfg), tracer=tracer,
                            cost_profiler=cprof)
        res = paged.run_continuous(sorted(reqs, key=lambda r: r.arrival))
        done = res.outputs
        print(f"paged: {res.admission_waves} admission waves, "
              f"prefill_tokens={res.prefill_tokens}, "
              f"peak_blocks={res.peak_blocks}, "
              f"kv_util={res.kv_utilization:.3f}, "
              f"waste_vs_padded={res.waste_vs_padded:.3f}")
        if pcfg.spec_tokens:
            print(f"speculate: {pcfg.spec_tokens} drafts/iter "
                  f"({args.drafter}), acceptance={res.acceptance_rate:.3f}, "
                  f"{res.steps} iterations for {res.generated_tokens} "
                  f"tokens ({res.iterations_per_token:.3f} it/tok), "
                  f"rolled_back={res.spec_rolled_blocks} blocks")
        if pcfg.chunk_tokens or pcfg.preempt:
            print(f"interleave: {res.prefill_chunks} chunks, "
                  f"stall={res.prefill_stall_s*1e3:.1f}ms, "
                  f"p99_itl={res.p99_inter_token_s*1e3:.2f}ms, "
                  f"preemptions={res.preemptions} "
                  f"({res.preempted_tokens} tokens recomputed)")
        if pcfg.prefix_cache:
            print(f"prefix: {res.prefix_hits}/{res.prefix_lookups} hits, "
                  f"hit_tokens={res.prefix_hit_tokens}, "
                  f"cow_forks={res.cow_forks}, "
                  f"evictions={res.prefix_evictions}, "
                  f"peak_residents={res.peak_residents}")
    elif args.continuous:
        res = engine.run_continuous(sorted(reqs, key=lambda r: r.arrival))
        done = res.outputs
    else:
        done = {}
        for b in get_scheduler(args.scheduler)(reqs, SchedulerConfig(max_batch=4)):
            res = engine.run_batch(b, true_lens={r.rid: r.true_output_len
                                                 for r in b.requests})
            done.update(res.outputs)
            for r in b.requests:
                mon.observe(r)
    dt = time.perf_counter() - t0
    total = sum(len(v) for v in done.values())
    print(f"served {len(done)} requests, {total} tokens in {dt:.2f}s "
          f"({total/dt:.1f} tok/s on CPU)")
    print(f"outputs_digest={_outputs_digest(done)}")
    if args.spec_tokens and cprof.spec_samples:
        print(f"measured acceptance EMA: {cprof.spec_acceptance:.3f} "
              f"({cprof.spec_accepted}/{cprof.spec_drafted} over "
              f"{cprof.spec_samples} verify passes)")
    print("monitor:", mon.metrics())
    _write_artifacts(args, mon, tracer, cprof, throughput=total / dt,
                     cal_models=cal_models)


if __name__ == "__main__":
    main()
