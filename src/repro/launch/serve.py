"""Serving launcher: UELLM pipeline on a real model.

  PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m --reduced \
      --requests 12 --scheduler slo-odbs

``--paged`` serves through the paged continuous-batching runtime instead
(block-table KV, per-prompt prefill, allocator-gated admission); the pool is
sized from ``--kv-budget`` bytes — the same budget surface SLO-ODBS uses.
``--prefix-cache`` layers the radix-tree prefix cache on top (shared-prefix
prompts prefill only their uncached suffix; ``--workload shared-prefix``
generates a template-heavy mix that exercises it), and ``--lookahead N``
lets admission skip a too-big queue head when a later request fits.
On a TPU pod this runs under the production mesh with the HELR-mesh plan;
on CPU (--reduced) it serves the reduced config end-to-end.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import SHAPES, get_config
from repro.core import (LengthPredictor, Monitor, ResourceProfiler,
                        SchedulerConfig, get_scheduler, helr_mesh)
from repro.core.profiler import PredictorConfig
from repro.data.workload import (SharedPrefixConfig, WorkloadConfig,
                                 gen_requests, gen_shared_prefix_requests,
                                 train_pairs)
from repro.models import api
from repro.serving import (EngineConfig, InferenceEngine, PagedEngine,
                           PagedEngineConfig)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--scheduler", default="slo-odbs",
                    choices=["slo-odbs", "slo-dbs", "odbs", "fifo", "s3"])
    ap.add_argument("--continuous", action="store_true",
                    help="beyond-paper continuous batching mode")
    ap.add_argument("--paged", action="store_true",
                    help="paged continuous batching (block-table KV cache)")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="radix-tree prefix sharing over the paged pool "
                         "(implies --paged)")
    ap.add_argument("--lookahead", type=int, default=0,
                    help="queue entries scanned past a blocked head "
                         "(paged admission)")
    ap.add_argument("--workload", default="alpaca",
                    choices=["alpaca", "shared-prefix"],
                    help="alpaca: lognormal Poisson mix; shared-prefix: "
                         "template-heavy prompts exercising the prefix cache")
    ap.add_argument("--kv-budget", type=float, default=2e6,
                    help="paged KV pool budget in bytes (shared with SLO-ODBS)")
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args()
    if args.prefix_cache:
        args.paged = True

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    print(f"serving {cfg.name} "
          f"(plan for production mesh: "
          f"{helr_mesh(get_config(args.arch), SHAPES['decode_32k']).name})")
    params = api.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    engine = InferenceEngine(cfg, params,
                             EngineConfig(max_batch=4, cache_len=64,
                                          max_new_tokens=args.max_new))

    if args.workload == "shared-prefix":
        reqs = gen_shared_prefix_requests(SharedPrefixConfig(
            n_requests=args.requests, n_templates=max(2, args.requests // 6),
            prefix_len=16, suffix_mean=2.0, vocab=cfg.vocab_size, seed=0))
        for r in reqs:
            r.tokens = [t % cfg.vocab_size for t in r.tokens[:32]]
    else:
        reqs = gen_requests(WorkloadConfig(n_requests=args.requests, seed=0,
                                           vocab=cfg.vocab_size))
        for r in reqs:
            r.tokens = [t % cfg.vocab_size for t in r.tokens[:16]]
    for r in reqs:
        r.input_len = len(r.tokens)
        r.true_output_len = r.true_output_len % args.max_new + 1

    pred = LengthPredictor(PredictorConfig(vocab=cfg.vocab_size), seed=0)
    toks, lens = train_pairs(WorkloadConfig(vocab=cfg.vocab_size), 256, seed=1)
    pred.fit(toks, lens, epochs=8)
    prof = ResourceProfiler(pred, cfg)
    mon = Monitor(prof)
    prof.profile(reqs)

    t0 = time.perf_counter()
    if args.paged:
        # size the block tables for the longest admitted prompt plus the
        # decode budget so any --max-new value is admissible
        max_prompt = max(len(r.tokens) for r in reqs)
        max_seq = max(64, -(-(max_prompt + args.max_new) // 8) * 8)
        pcfg = PagedEngineConfig.from_memory_budget(
            cfg, args.kv_budget, max_batch=4, block_size=8,
            max_seq_len=max_seq, max_new_tokens=args.max_new,
            prefix_cache=args.prefix_cache,
            admit_lookahead=args.lookahead)
        print(f"paged pool: {pcfg.n_blocks} blocks x {pcfg.block_size} slots "
              f"({args.kv_budget:.0f} B budget, "
              f"prefix_cache={'on' if pcfg.prefix_cache else 'off'})")
        paged = PagedEngine(cfg, params, pcfg, monitor=mon)
        res = paged.run_continuous(sorted(reqs, key=lambda r: r.arrival))
        done = res.outputs
        print(f"paged: {res.admission_waves} admission waves, "
              f"prefill_tokens={res.prefill_tokens}, "
              f"peak_blocks={res.peak_blocks}, "
              f"kv_util={res.kv_utilization:.3f}, "
              f"waste_vs_padded={res.waste_vs_padded:.3f}")
        if pcfg.prefix_cache:
            print(f"prefix: {res.prefix_hits}/{res.prefix_lookups} hits, "
                  f"hit_tokens={res.prefix_hit_tokens}, "
                  f"cow_forks={res.cow_forks}, "
                  f"evictions={res.prefix_evictions}, "
                  f"peak_residents={res.peak_residents}")
    elif args.continuous:
        res = engine.run_continuous(sorted(reqs, key=lambda r: r.arrival))
        done = res.outputs
    else:
        done = {}
        for b in get_scheduler(args.scheduler)(reqs, SchedulerConfig(max_batch=4)):
            res = engine.run_batch(b, true_lens={r.rid: r.true_output_len
                                                 for r in b.requests})
            done.update(res.outputs)
            for r in b.requests:
                mon.observe(r)
    dt = time.perf_counter() - t0
    total = sum(len(v) for v in done.values())
    print(f"served {len(done)} requests, {total} tokens in {dt:.2f}s "
          f"({total/dt:.1f} tok/s on CPU)")
    print("monitor:", mon.metrics())


if __name__ == "__main__":
    main()
