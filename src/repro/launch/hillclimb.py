import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# Must precede every other import (jax locks the device count on first init).

"""Perf hillclimb driver (EXPERIMENTS.md §Perf): for the three selected
cells, lower+compile the baseline and each optimization step, record the
roofline terms (analytic + compiled-HLO), and emit the iteration log.

  PYTHONPATH=src python -m repro.launch.hillclimb
"""
import dataclasses
import json
import pathlib

import jax

from repro.configs import SHAPES, get_config
from repro.launch import dryrun as dr
from repro.perf.cost_model import step_cost
from repro.sharding import compat

ART = pathlib.Path(__file__).resolve().parents[3] / "artifacts" / "perf"


def measure(cfg, shape, mp, tag):
    """Lower+compile with the given MeshPlan; return roofline record."""
    import repro.launch.dryrun as dryrun
    # monkey-free: reuse lower_cell but with an explicit plan
    orig = dryrun.pick_plan
    dryrun.pick_plan = lambda *a, **k: mp
    try:
        rec = dryrun.lower_cell(cfg, shape, multi_pod=False,
                                plan_kind=tag, verbose=True)
    finally:
        dryrun.pick_plan = orig
    return rec


def emit(name, steps):
    ART.mkdir(parents=True, exist_ok=True)
    (ART / f"{name}.json").write_text(json.dumps(steps, indent=1, default=str))
    print(f"== {name} ==")
    for s in steps:
        t = s["analytic"]["times_s"]
        print(f"  {s['plan_kind']:24s} comp={t['compute_s']*1e3:9.3f}ms "
              f"mem={t['memory_s']*1e3:8.3f}ms coll={t['collective_s']*1e3:8.3f}ms "
              f"dom={s['analytic']['bottleneck']:10s} hlo_flops={s['hlo_flops']:.3e}")


def climb_minicpm3():
    """Cell 1 (worst useful-FLOPs): minicpm3-4b × decode_32k.
    Hypothesis: the per-step latent->K/V expansion dominates compute
    (2·S·r·H·(dn+dv)·L ≈ 8.6e10·B FLOPs); absorbing W_uk/W_uv into the
    query/output removes it (~60× less attention compute) and flips the cell
    to memory-bound."""
    cfg = get_config("minicpm3-4b")
    shape = SHAPES["decode_32k"]
    base = dr.pick_plan(cfg, shape, multi_pod=False, which="baseline")
    steps = [measure(cfg, shape, base, "baseline_expanded")]

    opt = dataclasses.replace(
        base,
        plan=dataclasses.replace(base.plan, mla_absorbed=True),
        desc=dataclasses.replace(base.desc, mla_absorbed=True))
    opt = dataclasses.replace(opt, cost=step_cost(cfg, shape, opt.desc))
    steps.append(measure(cfg, shape, opt, "opt1_mla_absorbed"))
    emit("hillclimb_minicpm3_decode", steps)
    return steps


def climb_smollm():
    """Cell 2 (most collective-bound): smollm-135m × train_4k.
    Hypothesis A: TP-16 for a 135M model spends 4 allreduces/layer on
    activations (340 ms collective vs 34 ms compute); pure DP over all 256
    chips reduces collectives to one grad sync (~2·N·2B·(255/256)/chip
    ≈ 1.05 GB → ~21 ms) — a ~16× cut.
    Hypothesis B (beyond-paper): int8 gradient compression halves sync bytes
    vs bf16 (×4 vs fp32) — analytic, validated by the shard_map helper's
    correctness tests."""
    cfg = get_config("smollm-135m")
    shape = SHAPES["train_4k"]
    cands = {c.name: c for c in
             __import__("repro.core.deployer", fromlist=["candidate_plans"]
                        ).candidate_plans(cfg, shape, multi_pod=False)}
    base = dr.pick_plan(cfg, shape, multi_pod=False, which="baseline")
    steps = [measure(cfg, shape, base, "baseline_tp16")]
    dp = cands["dp256"]
    steps.append(measure(cfg, shape, dp, "opt1_pure_dp256"))
    # int8 grad sync: analytic only (GSPMD backward owns the collective);
    # record the projected terms
    proj = dict(steps[-1])
    coll = proj["analytic"]["coll_bytes_chip"] / 2.0
    t = dict(proj["analytic"]["times_s"])
    t["collective_s"] = t["collective_s"] / 2.0
    proj = {**proj, "plan_kind": "opt2_int8_gradsync(analytic)",
            "analytic": {**proj["analytic"], "coll_bytes_chip": coll,
                         "times_s": t},
            "hlo_flops": proj["hlo_flops"]}
    steps.append(proj)
    emit("hillclimb_smollm_train", steps)
    return steps


def climb_gemma2():
    """Cell 3 (most serving-representative): gemma2-27b × decode_32k.
    Hypothesis: the step is memory-bound (8.4 ms) on weight reads (3.4 GiB/chip
    → 4.2 ms) + KV reads (~3.4 GiB → 4.2 ms).  int8 KV cache halves the KV
    term (−2.1 ms); the window-layer ring buffers already cut KV 44% vs
    naive full-length caches (counted in the baseline)."""
    cfg = get_config("gemma2-27b")
    shape = SHAPES["decode_32k"]
    base = dr.pick_plan(cfg, shape, multi_pod=False, which="baseline")
    steps = [measure(cfg, shape, base, "baseline_bf16kv")]
    opt = dataclasses.replace(
        base, desc=dataclasses.replace(base.desc, kv_bytes_per=1))
    opt = dataclasses.replace(opt, cost=step_cost(cfg, shape, opt.desc))
    # int8 cache is exercised at reduced scale for accuracy (tests); the
    # full-cell lowering uses the same graph with int8 cache dtype
    steps.append(measure_int8_cache(cfg, shape, opt, "opt1_int8_kv"))
    emit("hillclimb_gemma2_decode", steps)
    return steps


def measure_int8_cache(cfg, shape, mp, tag):
    """Lower the decode cell with an int8 KV cache (dequant on read)."""
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch.mesh import make_production_mesh, mesh_shape_dict
    from repro.models import api
    from repro.sharding.specs import cache_specs_tree, param_specs
    import time

    mesh = make_production_mesh()
    mshape = mesh_shape_dict(mesh)
    plan = mp.plan
    specs_in = api.input_specs(cfg, shape, dtype=jnp.bfloat16)
    cache_struct = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, jnp.int8)
        if x.dtype == jnp.bfloat16 else x, specs_in["cache"])
    params_struct = jax.eval_shape(
        lambda: api.init_params(cfg, jax.random.PRNGKey(0), jnp.bfloat16))
    rec = {"arch": cfg.name, "shape": shape.name, "mesh": "16x16",
           "plan": mp.name, "plan_kind": tag, "n_chips": 256}

    def shardify(t):
        return jax.tree.map(lambda s: NamedSharding(mesh, s), t,
                            is_leaf=lambda s: isinstance(s, P))

    with compat.set_mesh(mesh):
        pspecs = param_specs(cfg, plan, params_struct, mshape)
        cspecs = cache_specs_tree(cfg, plan, cache_struct, mshape)
        ba = plan.batch_axes[0]

        def decode_fn(params, tokens, cache, kv_len):
            # dequantize (scale folded into a per-layer constant here; the
            # engine keeps per-row scales — same bytes, +1 small tensor)
            cache_f = jax.tree.map(
                lambda x: (x.astype(jnp.bfloat16) * jnp.bfloat16(0.05))
                if x.dtype == jnp.int8 else x, cache)
            logits, new_cache = api.decode_step(cfg, params, tokens, cache_f,
                                                kv_len, plan=plan)
            new_q = jax.tree.map(
                lambda new, old: jnp.clip(jnp.round(new / 0.05), -127, 127
                                          ).astype(jnp.int8)
                if old.dtype == jnp.int8 else new, new_cache, cache)
            return logits, new_q

        lowered = jax.jit(
            decode_fn,
            in_shardings=(shardify(pspecs), NamedSharding(mesh, P(ba)),
                          shardify(cspecs), NamedSharding(mesh, P(ba))),
            out_shardings=(NamedSharding(mesh, P(ba)), shardify(cspecs)),
            donate_argnums=(2,),
        ).lower(params_struct, specs_in["tokens"], cache_struct,
                specs_in["kv_len"])
        t0 = time.perf_counter()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.perf_counter() - t0, 2)
        rec["memory_analysis"] = dr._mem_dict(compiled.memory_analysis())
        ca = compat.cost_analysis_dict(compiled)
        rec["hlo_flops"] = float(ca.get("flops", 0.0))
        rec["hlo_bytes"] = float(ca.get("bytes accessed", 0.0))
        from repro.models.transformer import group_period
        rec["collectives"] = dr.parse_collectives(
            compiled.as_text(),
            loop_trips={"scan": float(cfg.n_layers // group_period(cfg))})
        ct = step_cost(cfg, shape, mp.desc)
        rec["analytic"] = {
            "flops_chip": ct.flops, "hbm_bytes_chip": ct.hbm_bytes,
            "coll_bytes_chip": ct.coll_bytes, "model_flops": ct.model_flops,
            "weight_bytes_chip": ct.weight_bytes_chip,
            "kv_bytes_chip": ct.kv_bytes_chip,
            "hbm_resident_chip": ct.hbm_resident,
            "times_s": ct.times(), "bottleneck": ct.bottleneck(),
        }
    ma = rec["memory_analysis"]
    print(f"  [int8kv] args/dev={ma.get('argument_size_in_bytes',0)/2**30:.2f}GiB")
    return rec


def main():
    climb_minicpm3()
    climb_smollm()
    climb_gemma2()


if __name__ == "__main__":
    main()
