"""Training launcher.

CPU demo:   PYTHONPATH=src python -m repro.launch.train --arch smollm-135m \
                --reduced --steps 50
TPU pod:    run under the production mesh — the HELR-mesh plan provides the
            shardings; this driver builds the same jit'd step the dry-run
            compiles (launch/scripts/train_pod.sh shows the multi-host form).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import SHAPES, get_config
from repro.core.deployer import helr_mesh
from repro.training import OptConfig, TrainConfig, init_training, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--optimizer", default="adamw",
                    choices=["adamw", "adafactor"])
    ap.add_argument("--ckpt-dir", default="")
    args = ap.parse_args()

    full_cfg = get_config(args.arch)
    plan = helr_mesh(full_cfg, SHAPES["train_4k"])
    print(f"production plan for {args.arch}: {plan.name} "
          f"(HBM/chip {plan.hbm_used/2**30:.1f} GiB)")
    cfg = full_cfg.reduced() if args.reduced else full_cfg

    tcfg = TrainConfig(opt=OptConfig(kind=args.optimizer, lr=1e-3))
    params, opt_state = init_training(cfg, jax.random.PRNGKey(0), tcfg,
                                      jnp.float32)
    step_fn = jax.jit(make_train_step(cfg, None, tcfg))
    mgr = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None

    rng = np.random.default_rng(0)
    base = rng.integers(2, cfg.vocab_size, size=(args.batch, args.seq))
    t0 = time.perf_counter()
    for step in range(args.steps):
        toks = jnp.asarray(np.roll(base, step % 8, axis=1), jnp.int32)
        batch = {"tokens": toks, "labels": jnp.roll(toks, -1, 1),
                 "mask": jnp.ones(toks.shape, jnp.float32)}
        params, opt_state, m = step_fn(params, opt_state, batch,
                                       jnp.asarray(step, jnp.int32))
        if step % 10 == 0 or step == args.steps - 1:
            print(f"step {step:4d} loss {float(m['loss']):.4f}")
        if mgr and (step + 1) % 20 == 0:
            mgr.save(step + 1, (params, opt_state), blocking=False)
    if mgr:
        mgr.wait()
    print(f"{args.steps} steps in {time.perf_counter()-t0:.1f}s")


if __name__ == "__main__":
    main()
