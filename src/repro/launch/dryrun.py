import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the device
# count on first init).  512 placeholder host devices back both the 16x16
# single-pod mesh and the 2x16x16 multi-pod mesh.

"""Multi-pod dry-run: lower + compile every (architecture × input shape) cell
on the production meshes, prove the sharding config is coherent, and capture
memory_analysis / cost_analysis / collective bytes for the roofline.

Usage:
  python -m repro.launch.dryrun --arch smollm-135m --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--plan helr|baseline]
  python -m repro.launch.dryrun --all --both-meshes
Artifacts land in artifacts/dryrun/<arch>__<shape>__<mesh>__<plan>.json.
"""
import argparse
import dataclasses
import json
import pathlib
import re
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import SHAPES, cell_is_runnable, get_config, list_archs
from repro.configs.base import ModelConfig, ShapeConfig
from repro.core.deployer import candidate_plans, helr_mesh
from repro.launch.mesh import make_production_mesh, mesh_shape_dict
from repro.models import api
from repro.models.transformer import group_period
from repro.perf.cost_model import step_cost
from repro.sharding import compat
from repro.sharding.plan import ShardingPlan
from repro.sharding.specs import cache_specs_tree, param_specs
from repro.training import OptConfig, TrainConfig, init_opt_state, \
    make_train_step, opt_state_specs

ART_DIR = pathlib.Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"

_COLL_RE = re.compile(
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1, "u8": 1,
          "pred": 1, "f64": 8, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
          "s16": 2, "u16": 2}


def _op_operand_bytes(line: str) -> float:
    """Sum operand tensor sizes on an HLO op line (result shape excluded —
    we count the line's RHS operands by re-parsing the argument list)."""
    # take shapes appearing after the '=' (op result shape is first token
    # before '='; operands appear in the call args)
    rhs = line.split("=", 1)[-1]
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(rhs):
        if dt not in _BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _BYTES[dt]
    return total


def parse_collectives(hlo_text: str, loop_trips: dict[str, float] | None = None
                      ) -> dict:
    """Sum collective operand bytes from HLO text.  Collectives inside while
    bodies are additionally multiplied by the known trip counts (layer-scan
    groups etc.) to correct XLA's count-once semantics; the caller passes
    {computation_name_fragment: trip_count}."""
    comps: dict[str, list[str]] = {}
    cur = None
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = re.match(r"^(%?[\w\.\-]+)\s*(\([^)]*\))?\s*->.*\{$", stripped)
        if m and not stripped.startswith("ROOT"):
            cur = m.group(1).lstrip("%")
            comps[cur] = []
            continue
        if stripped.startswith("ENTRY"):
            cur = "__entry__"
            comps[cur] = []
            continue
        if stripped == "}":
            continue
        if cur is not None:
            comps[cur].append(stripped)

    # which computations are while bodies (and their conds)
    while_bodies = set()
    for lines in comps.values():
        for ln in lines:
            if "while(" in ln or " while(" in ln or "= while" in ln:
                for m in re.finditer(r"(?:body|condition)=%?([\w\.\-]+)", ln):
                    while_bodies.add(m.group(1))

    raw = 0.0
    in_loop = 0.0
    by_kind: dict[str, float] = {}
    for name, lines in comps.items():
        looped = any(wb in name for wb in while_bodies) or name in while_bodies
        for ln in lines:
            m = _COLL_RE.search(ln)
            if not m or "=" not in ln:
                continue
            b = _op_operand_bytes(ln)
            raw += b
            by_kind[m.group(1)] = by_kind.get(m.group(1), 0.0) + b
            if looped:
                in_loop += b
    trips = max(loop_trips.values()) if loop_trips else 1.0
    corrected = (raw - in_loop) + in_loop * trips
    return {"raw_bytes": raw, "in_loop_bytes": in_loop,
            "corrected_bytes": corrected, "by_kind": by_kind,
            "loop_trip_assumed": trips}


def pick_plan(cfg: ModelConfig, shape: ShapeConfig, *, multi_pod: bool,
              which: str):
    cands = candidate_plans(cfg, shape, multi_pod=multi_pod)
    feas = [c for c in cands if c.fits] or cands
    if which == "helr":
        return min(feas, key=lambda c: c.step_time)
    return feas[0]          # baseline: first feasible (tp16_dp*)


def lower_cell(cfg: ModelConfig, shape: ShapeConfig, *, multi_pod: bool,
               plan_kind: str = "baseline", verbose: bool = True) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    mshape = mesh_shape_dict(mesh)
    mp = pick_plan(cfg, shape, multi_pod=multi_pod, which=plan_kind)
    plan = mp.plan
    dtype = jnp.bfloat16
    specs_in = api.input_specs(cfg, shape, dtype=dtype)
    params_struct = jax.eval_shape(
        lambda: api.init_params(cfg, jax.random.PRNGKey(0), dtype))
    record: dict = {
        "arch": cfg.name, "shape": shape.name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "plan": mp.name, "plan_kind": plan_kind,
        "n_chips": 512 if multi_pod else 256,
    }

    from jax.sharding import NamedSharding, PartitionSpec as P

    def shardify(spec_tree):
        return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                            is_leaf=lambda s: isinstance(s, P))

    with compat.set_mesh(mesh):
        pspecs = param_specs(cfg, plan, params_struct, mshape)
        batch_axes = plan.batch_axes if len(plan.batch_axes) > 1 else \
            (plan.batch_axes[0] if plan.batch_axes else None)

        if shape.kind == "train":
            opt_kind = mp.desc.optimizer
            tcfg = TrainConfig(opt=OptConfig(kind=opt_kind),
                               microbatches=plan.microbatches)
            opt_struct = jax.eval_shape(
                lambda: init_opt_state(params_struct, tcfg.opt))
            ospecs = opt_state_specs(pspecs, tcfg.opt)
            bspecs = jax.tree.map(lambda _: P(batch_axes), specs_in["batch"])
            step_fn = make_train_step(cfg, plan, tcfg)
            lowered = jax.jit(
                step_fn,
                in_shardings=(shardify(pspecs), shardify(ospecs),
                              shardify(bspecs), NamedSharding(mesh, P())),
                out_shardings=(shardify(pspecs), shardify(ospecs),
                               jax.tree.map(lambda _: NamedSharding(mesh, P()),
                                            {"loss": 0, "grad_norm": 0})),
                donate_argnums=(0, 1),
            ).lower(params_struct, opt_struct, specs_in["batch"],
                    jax.ShapeDtypeStruct((), jnp.int32))
        elif shape.kind == "prefill":
            cache_len = shape.seq_len
            cache_struct = api.cache_specs(cfg, shape.global_batch, cache_len,
                                           dtype=dtype)
            cspecs = cache_specs_tree(cfg, plan, cache_struct, mshape)
            bspecs = jax.tree.map(lambda _: P(batch_axes), specs_in["batch"])

            def prefill_fn(params, batch, kv_len):
                return api.prefill(cfg, params, batch, plan=plan,
                                   cache_len=cache_len, kv_len=kv_len)

            lowered = jax.jit(
                prefill_fn,
                in_shardings=(shardify(pspecs), shardify(bspecs),
                              NamedSharding(mesh, P(batch_axes))),
                out_shardings=(NamedSharding(mesh, P(batch_axes)),
                               shardify(cspecs)),
            ).lower(params_struct, specs_in["batch"], specs_in["kv_len"])
        else:  # decode
            cache_struct = specs_in["cache"]
            cspecs = cache_specs_tree(cfg, plan, cache_struct, mshape)

            def decode_fn(params, tokens, cache, kv_len):
                return api.decode_step(cfg, params, tokens, cache, kv_len,
                                       plan=plan)

            lowered = jax.jit(
                decode_fn,
                in_shardings=(shardify(pspecs),
                              NamedSharding(mesh, P(batch_axes)),
                              shardify(cspecs),
                              NamedSharding(mesh, P(batch_axes))),
                out_shardings=(NamedSharding(mesh, P(batch_axes)),
                               shardify(cspecs)),
                donate_argnums=(2,),
            ).lower(params_struct, specs_in["tokens"], cache_struct,
                    specs_in["kv_len"])

        t0 = time.perf_counter()
        compiled = lowered.compile()
        record["compile_s"] = round(time.perf_counter() - t0, 2)

        mem = compiled.memory_analysis()
        record["memory_analysis"] = _mem_dict(mem)
        ca = compat.cost_analysis_dict(compiled)
        record["hlo_flops"] = float(ca.get("flops", 0.0))
        record["hlo_bytes"] = float(ca.get("bytes accessed", 0.0))

        trips = {"scan": float(cfg.n_layers // group_period(cfg))}
        record["collectives"] = parse_collectives(
            compiled.as_text(), loop_trips=trips)

        # analytic roofline terms
        ct = step_cost(cfg, shape, mp.desc)
        record["analytic"] = {
            "flops_chip": ct.flops, "hbm_bytes_chip": ct.hbm_bytes,
            "coll_bytes_chip": ct.coll_bytes, "model_flops": ct.model_flops,
            "weight_bytes_chip": ct.weight_bytes_chip,
            "kv_bytes_chip": ct.kv_bytes_chip,
            "hbm_resident_chip": ct.hbm_resident,
            "times_s": ct.times(), "bottleneck": ct.bottleneck(),
        }
    if verbose:
        ma = record["memory_analysis"]
        print(f"  compiled in {record['compile_s']}s; "
              f"argbytes/dev={ma.get('argument_size_in_bytes', 0)/2**30:.2f}GiB "
              f"temp/dev={ma.get('temp_size_in_bytes', 0)/2**30:.2f}GiB "
              f"hlo_flops={record['hlo_flops']:.3e} "
              f"coll_raw={record['collectives']['raw_bytes']:.3e}B")
    return record


def _mem_dict(mem) -> dict:
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "alias_size_in_bytes",
              "generated_code_size_in_bytes"):
        try:
            out[k] = int(getattr(mem, k))
        except Exception:
            pass
    if not out:
        out["repr"] = str(mem)
    return out


def run_cell(arch: str, shape_name: str, *, multi_pod: bool, plan_kind: str
             ) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = cell_is_runnable(cfg, shape)
    mesh_tag = "2x16x16" if multi_pod else "16x16"
    if not ok:
        print(f"[skip] {arch} × {shape_name}: {why}")
        rec = {"arch": arch, "shape": shape_name, "mesh": mesh_tag,
               "skipped": why}
        ART_DIR.mkdir(parents=True, exist_ok=True)
        (ART_DIR / f"{arch}__{shape_name}__{mesh_tag}__{plan_kind}.json"
         ).write_text(json.dumps(rec, indent=1))
        return rec
    print(f"[cell] {arch} × {shape_name} on {mesh_tag} ({plan_kind})")
    try:
        rec = lower_cell(cfg, shape, multi_pod=multi_pod, plan_kind=plan_kind)
    except Exception as e:                        # noqa: BLE001
        print(f"  FAILED: {e}")
        traceback.print_exc()
        rec = {"arch": arch, "shape": shape_name, "mesh": mesh_tag,
               "error": str(e)}
    ART_DIR.mkdir(parents=True, exist_ok=True)
    out = ART_DIR / f"{arch}__{shape_name}__{mesh_tag}__{plan_kind}.json"
    out.write_text(json.dumps(rec, indent=1, default=str))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--plan", default="baseline", choices=["baseline", "helr"])
    args = ap.parse_args()

    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    archs = list_archs() if args.all or not args.arch else [args.arch]
    shapes = list(SHAPES) if args.all or not args.shape else [args.shape]

    failures = 0
    for mp in meshes:
        for arch in archs:
            for shp in shapes:
                rec = run_cell(arch, shp, multi_pod=mp, plan_kind=args.plan)
                if "error" in rec:
                    failures += 1
    if failures:
        raise SystemExit(f"{failures} cells failed")
    print("dry-run complete: all requested cells lowered + compiled")


if __name__ == "__main__":
    main()
