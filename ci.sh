#!/usr/bin/env bash
# CI smoke: tier-1 test suite + interpret-mode kernel validation.
#
#   ./ci.sh            # everything
#   ./ci.sh kernels    # kernel parity tests only (fast)
#   ./ci.sh serving    # paged-engine + prefix-cache runtime tests (fast)
#   ./ci.sh cluster    # cluster router/autoscaler tests + smoke (fast)
set -euo pipefail
cd "$(dirname "$0")"
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"

KERNEL_TESTS=(tests/test_kernels_flash.py tests/test_kernels_decode.py
              tests/test_kernels_wkv6.py tests/test_paged_attention.py)
SERVING_TESTS=(tests/test_paged_engine.py tests/test_prefix_cache.py)
CLUSTER_TESTS=(tests/test_cluster.py tests/test_workload.py)

cluster_smoke() {
    echo "== cluster smoke (2 simulated replicas, slo_aware router) =="
    python - <<'PY'
from repro.configs import get_config
from repro.core import get_scheduler
from repro.core.scheduler import SchedulerConfig
from repro.data.workload import WorkloadConfig, gen_requests
from repro.serving import simulate_cluster

reqs = gen_requests(WorkloadConfig(n_requests=48, arrival_rate=16.0,
                                   slo_lo=5.0, slo_hi=50.0, seed=1))
res = simulate_cluster(reqs, get_config("chatglm2-6b"),
                       get_scheduler("slo-odbs"), SchedulerConfig(),
                       n_replicas=2, router="slo_aware")
assert len(res.finished) + len(res.shed) == 48, res.summary()
assert res.peak_replicas == 2
assert 0.0 <= res.slo_attainment <= 1.0
print("cluster smoke:", res.summary())
PY
}

if [[ "${1:-}" == "kernels" ]]; then
    python -m pytest -q "${KERNEL_TESTS[@]}"
    exit 0
fi

if [[ "${1:-}" == "serving" ]]; then
    python -m pytest -q "${SERVING_TESTS[@]}"
    exit 0
fi

if [[ "${1:-}" == "cluster" ]]; then
    python -m pytest -q "${CLUSTER_TESTS[@]}"
    cluster_smoke
    exit 0
fi

echo "== tier-1 (kernel files deferred to the dedicated step below) =="
IGNORES=()
for t in "${KERNEL_TESTS[@]}"; do IGNORES+=("--ignore=$t"); done
python -m pytest -x -q "${IGNORES[@]}"

echo "== kernel parity (pallas interpret + xla vs oracle) =="
python -m pytest -q "${KERNEL_TESTS[@]}"

cluster_smoke

echo "ci.sh: all green"
