#!/usr/bin/env bash
# CI smoke: tier-1 test suite + interpret-mode kernel validation.
#
#   ./ci.sh            # everything
#   ./ci.sh kernels    # kernel parity tests only (fast)
#   ./ci.sh serving    # paged-engine + prefix-cache runtime tests (fast)
#   ./ci.sh cluster    # cluster router/autoscaler tests + smoke (fast)
set -euo pipefail
cd "$(dirname "$0")"
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"

KERNEL_TESTS=(tests/test_kernels_flash.py tests/test_kernels_decode.py
              tests/test_kernels_wkv6.py tests/test_paged_attention.py)
SERVING_TESTS=(tests/test_paged_engine.py tests/test_prefix_cache.py
               tests/test_speculative.py)
CLUSTER_TESTS=(tests/test_cluster.py tests/test_workload.py)

interleave_smoke() {
    echo "== interleave smoke (chunked prefill + forced preemption) =="
    python - <<'PY'
import copy, jax, jax.numpy as jnp
from repro.configs import get_config
from repro.core.types import Batch, Request
from repro.models import api
from repro.serving import (EngineConfig, InferenceEngine, PagedEngine,
                           PagedEngineConfig)

cfg = get_config("smollm-135m").reduced()
params = api.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
# r0: 16-token prompt -> 2 chunks at chunk_tokens=8; slack SLO (evictable).
# r1: tight arrival that only fits once r0's blocks are reclaimed.
reqs = [Request(rid=0, tokens=[3] * 16, input_len=16, slo=1000.0,
                arrival=0.0, true_output_len=6),
        Request(rid=1, tokens=[5] * 8, input_len=8, slo=0.001,
                arrival=0.0, true_output_len=4)]
ref = InferenceEngine(cfg, params,
                      EngineConfig(max_batch=2, cache_len=32,
                                   max_new_tokens=8)).run_batch(
    Batch(requests=[copy.copy(r) for r in reqs]),
    true_lens={r.rid: r.true_output_len for r in reqs})
eng = PagedEngine(cfg, params, PagedEngineConfig(
    max_batch=2, block_size=8, n_blocks=5, max_seq_len=32,
    max_new_tokens=8, chunk_tokens=8, preempt=True))
res = eng.run_continuous([copy.copy(r) for r in reqs])
assert res.preemptions >= 1, res.preemptions
assert res.prefill_chunks >= 4, res.prefill_chunks   # 2 chunks + recompute
assert all(res.outputs[r.rid] == ref.outputs[r.rid] for r in reqs)
print(f"interleave smoke: chunks={res.prefill_chunks} "
      f"preemptions={res.preemptions} (token-identical)")
PY
}

spec_smoke() {
    echo "== speculative smoke (n-gram drafter, token-identity) =="
    python - <<'PY'
import copy, jax, jax.numpy as jnp
from repro.configs import get_config
from repro.core.types import Batch, Request
from repro.models import api
from repro.serving import (EngineConfig, InferenceEngine, PagedEngine,
                           PagedEngineConfig)

cfg = get_config("smollm-135m").reduced()
params = api.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
# cycled prompts: the n-gram drafter must land at least some accepts, and
# greedy acceptance must keep outputs exactly equal to sequential decode
reqs = [Request(rid=i, tokens=([7 + i, 11, 13 + i, 17] * 6)[:20],
                input_len=20, slo=60.0, arrival=0.0, true_output_len=10)
        for i in range(4)]
ref = InferenceEngine(cfg, params,
                      EngineConfig(max_batch=4, cache_len=48,
                                   max_new_tokens=12)).run_batch(
    Batch(requests=[copy.copy(r) for r in reqs]),
    true_lens={r.rid: r.true_output_len for r in reqs})
eng = PagedEngine(cfg, params, PagedEngineConfig(
    max_batch=2, block_size=8, n_blocks=24, max_seq_len=48,
    max_new_tokens=12, spec_tokens=4))
res = eng.run_continuous([copy.copy(r) for r in reqs])
assert all(res.outputs[r.rid] == ref.outputs[r.rid] for r in reqs), \
    "speculation changed outputs"
assert res.drafted_tokens > 0, "drafter never proposed"
print(f"spec smoke: {res.steps} iterations for {res.generated_tokens} "
      f"tokens, acceptance={res.acceptance_rate:.2f} (token-identical)")
PY
}

cluster_smoke() {
    echo "== cluster smoke (2 simulated replicas, slo_aware router) =="
    python - <<'PY'
from repro.configs import get_config
from repro.core import get_scheduler
from repro.core.scheduler import SchedulerConfig
from repro.data.workload import WorkloadConfig, gen_requests
from repro.serving import simulate_cluster

reqs = gen_requests(WorkloadConfig(n_requests=48, arrival_rate=16.0,
                                   slo_lo=5.0, slo_hi=50.0, seed=1))
res = simulate_cluster(reqs, get_config("chatglm2-6b"),
                       get_scheduler("slo-odbs"), SchedulerConfig(),
                       n_replicas=2, router="slo_aware")
assert len(res.finished) + len(res.shed) == 48, res.summary()
assert res.peak_replicas == 2
assert 0.0 <= res.slo_attainment <= 1.0
print("cluster smoke:", res.summary())
PY
}

fault_smoke() {
    echo "== fault smoke (scripted crash + retry, token identity, leak audit) =="
    python - <<'PY'
from repro.configs import get_config
from repro.core import LengthPredictor, Monitor, ResourceProfiler, get_scheduler
from repro.core.profiler import PredictorConfig
from repro.core.scheduler import SchedulerConfig
from repro.data.workload import WorkloadConfig, gen_requests
from repro.obs.export import metrics_payload, validate_metrics
from repro.serving import (FaultEvent, HealthConfig, RetryConfig,
                           simulate_cluster)

cfg = get_config("chatglm2-6b")
reqs = gen_requests(WorkloadConfig(n_requests=48, arrival_rate=12.0,
                                   slo_lo=8.0, slo_hi=50.0, seed=3))
mon = Monitor(ResourceProfiler(LengthPredictor(PredictorConfig(), seed=0),
                               cfg), update_on_miss=False)
res = simulate_cluster(reqs, cfg, get_scheduler("slo-odbs"),
                       SchedulerConfig(), n_replicas=2, router="slo_aware",
                       monitor=mon,
                       faults=[FaultEvent(t=1.0, kind="crash", rid=0)],
                       retry=RetryConfig(budget=2),
                       health=HealthConfig(check_interval=0.2,
                                           detect_lag=0.5))
# crash detected, lost work recovered, every request has exactly one fate
assert mon.stats.failures_by_kind == {"crash": 1}, mon.stats.failures_by_kind
assert mon.stats.request_retries > 0
assert len(res.finished) + len(res.shed) == len(res.requests)
payload = metrics_payload("fault_smoke",
                          slo_attainment=res.slo_attainment,
                          monitor=mon.metrics())
errs = validate_metrics(payload)
assert not errs, errs
assert payload["monitor"]["faults"]["retries"] > 0
print(f"fault smoke: attainment={res.slo_attainment:.3f} "
      f"retries={mon.stats.request_retries} (metrics schema valid)")
PY
    python - <<'PY'
import copy, jax, jax.numpy as jnp
from repro.configs import get_config
from repro.core.types import Request
from repro.models import api
from repro.serving import PagedEngine, PagedEngineConfig

cfg = get_config("smollm-135m").reduced()
params = api.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)

def engine():
    return PagedEngine(cfg, params, PagedEngineConfig(
        max_batch=2, block_size=8, n_blocks=24, max_seq_len=48,
        max_new_tokens=10))

reqs = [Request(rid=i, tokens=[3 + i] * 12, input_len=12, slo=60.0,
                arrival=0.0, true_output_len=8) for i in range(2)]
ref = engine().run_continuous([copy.copy(r) for r in reqs])
# crash rid=0 two tokens in; every engine run ends with the allocator
# leak audit (run_continuous raises on any leaked block)
crashed = engine().run_continuous([copy.copy(r) for r in reqs],
                                  abort_at={0: 2})
assert crashed.errors == {0: "aborted"}, crashed.errors
partial = crashed.outputs[0]
resumed = engine().run_continuous([copy.copy(reqs[0])],
                                  resume={0: partial})
assert partial == ref.outputs[0][:len(partial)]
assert resumed.outputs[0] == ref.outputs[0], "retry not token-identical"
print(f"fault smoke: abort@{len(partial)} -> resume token-identical, "
      f"zero leaked blocks")
PY
}

fleet_smoke() {
    echo "== fleet smoke (2 models x 2 tiers, model-aware routing, v6 metrics) =="
    python -m repro.launch.serve --arch chatglm2-6b \
        --models "chatglm2-6b:0.6,qwen2-1.5b:0.4" --requests 32 \
        --replicas 2 --router slo_aware --fleet joint \
        --metrics-json /tmp/fleet_m.json > /dev/null
    python - <<'PY'
import json
from repro.obs.export import METRICS_SCHEMA_VERSION, validate_metrics

m = json.load(open("/tmp/fleet_m.json"))
errs = validate_metrics(m)
assert not errs, errs
assert m["schema"] == METRICS_SCHEMA_VERSION == 6, m["schema"]
by_key = m["monitor"].get("slo_by_key", {})
models = {k for k in by_key if k.startswith("model:")}
tiers = {k for k in by_key if k.startswith("tier:")}
assert {"model:chatglm2-6b", "model:qwen2-1.5b"} <= models, by_key
assert tiers, by_key
for k, blk in by_key.items():
    assert {"observed", "violations", "attainment"} <= set(blk), (k, blk)
print(f"fleet smoke: per-model attainment "
      f"{ {k: by_key[k]['attainment'] for k in sorted(models)} }, "
      f"tiers { {k: by_key[k]['attainment'] for k in sorted(tiers)} }")
PY
}

traced_smoke() {
    echo "== traced smoke (serve.py --paged --trace/--metrics-json) =="
    python -m repro.launch.serve --paged --preempt --speculate \
        --chunk-tokens 8 --requests 8 \
        --trace /tmp/trace.json --metrics-json /tmp/m.json > /dev/null
    python - <<'PY'
import json
from repro.obs.export import validate_metrics, validate_trace

obj = json.load(open("/tmp/trace.json"))
errs = validate_trace(obj)
assert not errs, errs
names = {e["name"] for e in obj["traceEvents"] if e["ph"] != "M"}
need = {"queued", "admitted", "prefill_chunk", "finish"}
assert need <= names, need - names
metrics = json.load(open("/tmp/m.json"))
errs = validate_metrics(metrics)
assert not errs, errs
assert metrics["schema"] >= 4, metrics["schema"]   # v4: per-replica drift
mon = metrics["monitor"]
for key in ("queue_wait", "ttft", "itl", "e2e"):
    assert {"p50", "p95", "p99"} <= set(mon[key]), key
print(f"traced smoke: {len(obj['traceEvents'])} events, "
      f"p99_e2e={mon['e2e']['p99']:.3f}s (both artifacts valid)")
PY
}

profile_smoke() {
    echo "== profile smoke (--profile-out / --profile-in round trip) =="
    python -m repro.launch.serve --paged --speculate --chunk-tokens 8 \
        --requests 8 --profile-out /tmp/prof.json > /tmp/serve_a.log
    python -m repro.launch.serve --paged --speculate --chunk-tokens 8 \
        --requests 8 --profile-in /tmp/prof.json > /tmp/serve_b.log
    da=$(grep -o 'outputs_digest=[0-9a-f]*' /tmp/serve_a.log)
    db=$(grep -o 'outputs_digest=[0-9a-f]*' /tmp/serve_b.log)
    if [[ -z "$da" || "$da" != "$db" ]]; then
        echo "profile smoke: calibrated pricing changed outputs" \
             "('$da' vs '$db')"
        exit 1
    fi
    python - <<'PY'
import json
from repro.obs import CalibratedLatencyModel, CostProfiler

a = CostProfiler.load("/tmp/prof.json")
b = CostProfiler.from_json(a.to_json())
assert a.to_json() == b.to_json(), "profile registry not byte-stable"
cov = a.coverage()
assert any(c["samples"] > 0 for c in cov.values()), cov
for key, ca in a.cells.items():
    cb = b.cells[key]
    assert ca.ema_s == cb.ema_s and ca.mean_s == cb.mean_s \
        and ca.ratio_ema == cb.ratio_ema, key
# v2 registries carry per-replica sub-profiles; they must survive the
# round trip cell-identical too (serve runs on replica 0)
assert set(a.replica_profiles) == set(b.replica_profiles)
for rid, sub in a.replica_profiles.items():
    for key, ca in sub.cells.items():
        cb = b.replica_profiles[rid].cells[key]
        assert ca.ema_s == cb.ema_s and ca.ratio_ema == cb.ratio_ema, \
            (rid, key)
# a legacy flat (v1) registry still loads — as a fleet-only profile —
# and any other version is refused with a clear error
fleet = a.to_json()["fleet"]
v1 = {"profile_version": 1, "alpha": a.alpha, "drift_tol": a.drift_tol,
      "drift_min_samples": a.drift_min_samples, "drift_events": 1,
      "cells": [{"key": c["key"], "count": c["count"],
                 "ema_s": c["ema_s"], "total_s": c["total_s"],
                 "hist": c["hist"], "ratio_count": c["ratio_count"],
                 "ratio_ema": (c["ratio_num"] / c["ratio_den"])
                 if c["ratio_den"] else 0.0}
                for c in fleet["cells"]],
      "residual": fleet["residual"],
      "phase_ratio": {ph: [pr[0], pr[1] / pr[2] if pr[2] else 0.0]
                      for ph, pr in fleet["phase_ratio"].items()},
      "spec": {"drafted": 0, "accepted": 0, "samples": 0,
               "ema": 0.5, "bootstrap": 0.5}}
old = CostProfiler.from_json(json.loads(json.dumps(v1)))
assert old.replica_profiles == {}, "v1 import must be fleet-only"
assert len(old.cells) == len(a.cells)
assert old.drift_events == 1
try:
    CostProfiler.from_json({"profile_version": 99})
except ValueError as e:
    assert "profile_version" in str(e), e
else:
    raise AssertionError("unknown profile_version was not refused")
print(f"profile smoke: {len(a.cells)} cells "
      f"({len(a.replica_profiles)} replica sub-profiles) round-trip "
      f"identical, v1 loads fleet-only, v99 refused, "
      f"coverage={json.dumps(cov)} (token-identical serve)")
PY
}

validate_artifacts() {
    echo "== bench artifact validation (shared metrics schema) =="
    python - <<'PY'
import glob, json, sys
from repro.obs.export import validate_metrics

files = sorted(glob.glob("artifacts/bench/BENCH_*.json"))
bad = 0
for f in files:
    errs = validate_metrics(json.load(open(f)))
    if errs:
        print(f"{f}: INVALID {errs}")
        bad += 1
if bad:
    sys.exit(1)
print(f"validate_artifacts: {len(files)} BENCH_*.json artifacts valid"
      if files else "validate_artifacts: no artifacts present (ok)")
PY
}

if [[ "${1:-}" == "kernels" ]]; then
    python -m pytest -q "${KERNEL_TESTS[@]}"
    exit 0
fi

if [[ "${1:-}" == "serving" ]]; then
    python -m pytest -q "${SERVING_TESTS[@]}"
    interleave_smoke
    spec_smoke
    exit 0
fi

if [[ "${1:-}" == "cluster" ]]; then
    python -m pytest -q "${CLUSTER_TESTS[@]}" tests/test_faults.py
    cluster_smoke
    fleet_smoke
    fault_smoke
    exit 0
fi

echo "== tier-1 (kernel files deferred to the dedicated step below) =="
IGNORES=()
for t in "${KERNEL_TESTS[@]}"; do IGNORES+=("--ignore=$t"); done
python -m pytest -x -q "${IGNORES[@]}"

echo "== kernel parity (pallas interpret + xla vs oracle) =="
python -m pytest -q "${KERNEL_TESTS[@]}"

interleave_smoke
spec_smoke
cluster_smoke
fleet_smoke
fault_smoke
traced_smoke
profile_smoke
validate_artifacts

echo "ci.sh: all green"
