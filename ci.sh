#!/usr/bin/env bash
# CI smoke: tier-1 test suite + interpret-mode kernel validation.
#
#   ./ci.sh            # everything
#   ./ci.sh kernels    # kernel parity tests only (fast)
#   ./ci.sh serving    # paged-engine + prefix-cache runtime tests (fast)
set -euo pipefail
cd "$(dirname "$0")"
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"

KERNEL_TESTS=(tests/test_kernels_flash.py tests/test_kernels_decode.py
              tests/test_kernels_wkv6.py tests/test_paged_attention.py)
SERVING_TESTS=(tests/test_paged_engine.py tests/test_prefix_cache.py)

if [[ "${1:-}" == "kernels" ]]; then
    python -m pytest -q "${KERNEL_TESTS[@]}"
    exit 0
fi

if [[ "${1:-}" == "serving" ]]; then
    python -m pytest -q "${SERVING_TESTS[@]}"
    exit 0
fi

echo "== tier-1 (kernel files deferred to the dedicated step below) =="
IGNORES=()
for t in "${KERNEL_TESTS[@]}"; do IGNORES+=("--ignore=$t"); done
python -m pytest -x -q "${IGNORES[@]}"

echo "== kernel parity (pallas interpret + xla vs oracle) =="
python -m pytest -q "${KERNEL_TESTS[@]}"

echo "ci.sh: all green"
